//! Method bake-off on one model: run every pre-quantization transform the
//! paper evaluates through the full pipeline and print quantization time,
//! rotated-activation quantization error, and end-to-end perplexity.
//!
//!     cargo run --release --example quantize_model [artifacts_dir] [model]

use std::sync::Arc;

use anyhow::Result;
use singlequant::eval::ppl::perplexity;
use singlequant::model::Weights;
use singlequant::pipeline::{quantize, Method, PipelineOptions};
use singlequant::runtime::{Engine, ModelRunner};
use singlequant::util::bench::Table;
use singlequant::util::sqt::SqtFile;

fn main() -> Result<()> {
    let mut args = std::env::args().skip(1);
    let dir = args.next().unwrap_or_else(|| "artifacts".into());
    let model = args.next().unwrap_or_else(|| "sq-m".into());

    let engine = Arc::new(Engine::new(&dir)?);
    let cfg = engine.config(&model)?;
    let weights = Weights::load(&format!("{dir}/ckpt/{model}.sqt"))?;
    let calib = SqtFile::load(&format!("{dir}/data/corpus_wiki_train.sqt"))?
        .get("tokens")?.as_u16()?.to_vec();
    let eval = SqtFile::load(&format!("{dir}/data/corpus_wiki_eval.sqt"))?
        .get("tokens")?.as_u16()?.to_vec();

    let methods: Vec<Method> = vec![
        Method::Fp16,
        Method::Rtn,
        Method::SmoothQuant { alpha: 0.5 },
        Method::Awq { grid: 10 },
        Method::QuaRot,
        Method::DuQuant { steps: 16 },
        Method::SpinQuant { steps: 100 },
        Method::FlatQuant { steps: 60 },
        Method::singlequant(),
    ];

    let mut table = Table::new(
        &format!("W4A4 method bake-off on {model}"),
        &["method", "quant time (s)", "wiki ppl↓", "mean rot defect"],
    );
    for method in methods {
        let label = method.label();
        let opts = PipelineOptions { method, ..Default::default() };
        let t0 = std::time::Instant::now();
        let qm = quantize(&cfg, &weights, &calib, &opts)?;
        let qt = t0.elapsed().as_secs_f64();
        let runner = ModelRunner::new(engine.clone(), &qm)?;
        let ppl = perplexity(&runner, &eval, cfg.score_seq, 8)?;
        let defect = if qm.rots.is_empty() {
            0.0
        } else {
            qm.rots.values().map(|r| r.defect()).sum::<f32>() / qm.rots.len() as f32
        };
        println!("  {label}: {qt:.2}s, ppl {ppl:.3}");
        table.row(vec![
            label,
            format!("{qt:.3}"),
            format!("{ppl:.3}"),
            format!("{defect:.2e}"),
        ]);
    }
    table.print();
    Ok(())
}
