"""SQT: the tiny named-tensor container format shared between the Python
build path and the Rust runtime.

Layout (all little-endian):

    magic   b"SQT1"
    u32     n_tensors
    u32     meta_len        # UTF-8 JSON blob (free-form metadata)
    bytes   meta
    n_tensors x:
        u16   name_len
        bytes name          # UTF-8
        u8    dtype         # 0=f32 1=i32 2=u16 3=u8
        u8    ndim
        u32   dims[ndim]
        u64   nbytes
        bytes data          # raw little-endian

The Rust twin lives in `rust/src/util/sqt.rs`; keep the two in sync.
"""
from __future__ import annotations

import json
import struct
from typing import Dict, Tuple

import numpy as np

MAGIC = b"SQT1"

_DTYPES = {
    np.dtype(np.float32): 0,
    np.dtype(np.int32): 1,
    np.dtype(np.uint16): 2,
    np.dtype(np.uint8): 3,
}
_RDTYPES = {v: k for k, v in _DTYPES.items()}


def save(path: str, tensors: Dict[str, np.ndarray], meta: dict | None = None) -> None:
    """Write `tensors` (+ optional JSON metadata) to `path`."""
    meta_bytes = json.dumps(meta or {}).encode("utf-8")
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", len(tensors)))
        f.write(struct.pack("<I", len(meta_bytes)))
        f.write(meta_bytes)
        for name in sorted(tensors):
            arr = np.ascontiguousarray(tensors[name])
            if arr.dtype not in _DTYPES:
                raise ValueError(f"unsupported dtype {arr.dtype} for tensor {name!r}")
            nb = name.encode("utf-8")
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            f.write(struct.pack("<BB", _DTYPES[arr.dtype], arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            raw = arr.tobytes()
            f.write(struct.pack("<Q", len(raw)))
            f.write(raw)


def load(path: str) -> Tuple[Dict[str, np.ndarray], dict]:
    """Read an SQT file; returns (tensors, metadata)."""
    with open(path, "rb") as f:
        if f.read(4) != MAGIC:
            raise ValueError(f"{path}: bad magic (not an SQT file)")
        (n_tensors,) = struct.unpack("<I", f.read(4))
        (meta_len,) = struct.unpack("<I", f.read(4))
        meta = json.loads(f.read(meta_len).decode("utf-8")) if meta_len else {}
        tensors: Dict[str, np.ndarray] = {}
        for _ in range(n_tensors):
            (name_len,) = struct.unpack("<H", f.read(2))
            name = f.read(name_len).decode("utf-8")
            dtype_code, ndim = struct.unpack("<BB", f.read(2))
            dims = struct.unpack(f"<{ndim}I", f.read(4 * ndim)) if ndim else ()
            (nbytes,) = struct.unpack("<Q", f.read(8))
            raw = f.read(nbytes)
            arr = np.frombuffer(raw, dtype=_RDTYPES[dtype_code]).reshape(dims).copy()
            tensors[name] = arr
        return tensors, meta
