"""Layer-2 JAX model: LLaMA-style decoder (+ Mixtral-style MoE variant).

Defines the full-precision and W4A4/W4A16 quantized forward graphs that
`aot.py` lowers to HLO text for the Rust runtime. Three graph families per
model configuration:

* ``score``   — tokens[B,T] -> logits[B,T,V] (perplexity / MC scoring /
  calibration cross-checks).
* ``prefill`` — tokens[B,T] -> (last-position logits[B,V], K/V caches
  [L,B,H,Tmax,dh]) for serving.
* ``decode``  — (token[B], pos, K, V) -> (logits[B,V], K', V') one
  autoregressive step against the cache.

Quantized graphs replace every linear with

    kron_rotate(x, R1, R2)  ->  per-token int-b fake-quant  ->  GEMM

(the Layer-1 Pallas kernels). The rotation factors, activation-clip scalars,
and the (already rotated + weight-quantized by the Rust pipeline) weights
are **runtime parameters**, so one artifact serves every method: identity
factors = plain RTN; Hadamard factors = QuaRot; learned factors = SpinQuant;
ART/URT closed-form factors = SingleQuant. Scale/fold-based methods
(SmoothQuant, AWQ) are folded into the weights Rust-side and fed identity
rotations. ``w4a16`` lowers the same graph with activation quantization
disabled (weight-only tables).

Parameter interchange: parameters travel as a flat list ordered by
``param_layout(cfg, mode)``; `aot.py` writes the layout JSON next to each
artifact so the Rust side can assemble inputs by name.

Embeddings, the LM head, and norms stay full-precision (paper convention).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import kernels
from .data import VOCAB_SIZE

EPS = 1e-5


# ---------------------------------------------------------------------------
# Configs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    vocab_size: int = VOCAB_SIZE
    max_seq: int = 160          # serving cache capacity (prompt + generation)
    score_seq: int = 96         # fixed T of the score graph
    rope_theta: float = 10000.0
    n_experts: int = 0          # 0 = dense; >0 = Mixtral-style MoE
    top_k: int = 2

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0


def kron_factor(n: int) -> Tuple[int, int]:
    """Algorithm 1: n = n1*n2 with n2 the power of two nearest sqrt(n)."""
    root = math.sqrt(n)
    n2 = 1
    k = 0
    while (1 << k) <= n:
        a = 1 << k
        if n % a == 0 and abs(a - root) < abs(n2 - root):
            n2 = a
        k += 1
    return n // n2, n2


# The model zoo. Sizes are scaled to this single-core testbed while keeping
# the paper's model-size *axis* (small -> large -> MoE); see DESIGN.md.
CONFIGS: Dict[str, ModelConfig] = {c.name: c for c in [
    ModelConfig("sq-xs", d_model=64, n_layers=2, n_heads=4, d_ff=128),
    ModelConfig("sq-s", d_model=64, n_layers=3, n_heads=4, d_ff=160),
    ModelConfig("sq-m", d_model=96, n_layers=4, n_heads=4, d_ff=256),
    ModelConfig("sq-l", d_model=128, n_layers=5, n_heads=4, d_ff=320),
    ModelConfig("sq-xl", d_model=160, n_layers=6, n_heads=5, d_ff=416),
    ModelConfig("sq-moe", d_model=96, n_layers=3, n_heads=4, d_ff=160,
                n_experts=4, top_k=2),
]}
# The chat (Vicuna-like) variant shares the sq-m architecture.
CONFIGS["sq-m-chat"] = dataclasses.replace(CONFIGS["sq-m"], name="sq-m-chat")


# ---------------------------------------------------------------------------
# Parameter layout
# ---------------------------------------------------------------------------


ROT_SITES = ("qkv", "o", "mlp", "down")  # rotation/quantization sites per layer


def _layer_weight_names(cfg: ModelConfig, i: int) -> List[str]:
    p = f"l{i:02d}"
    names = [f"{p}.an", f"{p}.wq", f"{p}.wk", f"{p}.wv", f"{p}.wo", f"{p}.mn"]
    if cfg.is_moe:
        names.append(f"{p}.router")
        for e in range(cfg.n_experts):
            names += [f"{p}.x{e}.wg", f"{p}.x{e}.wu", f"{p}.x{e}.wd"]
    else:
        names += [f"{p}.wg", f"{p}.wu", f"{p}.wd"]
    return names


def weight_names(cfg: ModelConfig) -> List[str]:
    names = ["emb.tok"]
    for i in range(cfg.n_layers):
        names += _layer_weight_names(cfg, i)
    names += ["out.norm", "out.head"]
    return names


def rot_names(cfg: ModelConfig) -> List[str]:
    names = []
    for i in range(cfg.n_layers):
        p = f"l{i:02d}"
        for site in ROT_SITES:
            names += [f"{p}.rot_{site}.r1", f"{p}.rot_{site}.r2", f"{p}.clip_{site}"]
    return names


def param_layout(cfg: ModelConfig, mode: str) -> List[str]:
    """Canonical ordered parameter names for a graph family.

    ``fp`` graphs take only weights; quantized graphs take weights followed
    by rotation factors and activation-clip scalars.
    """
    if mode == "fp":
        return weight_names(cfg)
    return weight_names(cfg) + rot_names(cfg)


def param_shape(cfg: ModelConfig, name: str) -> Tuple[int, ...]:
    d, ff, v = cfg.d_model, cfg.d_ff, cfg.vocab_size
    base = name.split(".")[-1]
    if name == "emb.tok":
        return (v, d)
    if name == "out.norm":
        return (d,)
    if name == "out.head":
        return (d, v)
    if base in ("an", "mn"):
        return (d,)
    if base in ("wq", "wk", "wv", "wo"):
        return (d, d)
    if base in ("wg", "wu"):
        return (d, ff)
    if base == "wd":
        return (ff, d)
    if base == "router":
        return (d, cfg.n_experts)
    if base == "r1" or base == "r2":
        site = name.split(".")[-2].removeprefix("rot_")
        n = ff if site == "down" else d
        n1, n2 = kron_factor(n)
        return (n1, n1) if base == "r1" else (n2, n2)
    if base.startswith("clip_"):
        return ()
    raise KeyError(name)


def init_params(cfg: ModelConfig, seed: int = 0) -> Dict[str, jnp.ndarray]:
    """Scaled-normal init for training (norms at 1)."""
    rng = np.random.default_rng(seed)
    params: Dict[str, jnp.ndarray] = {}
    for name in weight_names(cfg):
        shape = param_shape(cfg, name)
        base = name.split(".")[-1]
        if base in ("an", "mn") or name == "out.norm":
            arr = np.ones(shape, np.float32)
        else:
            fan_in = shape[0]
            arr = rng.normal(0.0, 1.0 / math.sqrt(fan_in), size=shape).astype(np.float32)
        params[name] = jnp.asarray(arr)
    return params


def identity_rotations(cfg: ModelConfig) -> Dict[str, jnp.ndarray]:
    """Identity rotation factors + unit clips (plain-RTN baseline inputs)."""
    out: Dict[str, jnp.ndarray] = {}
    for name in rot_names(cfg):
        shape = param_shape(cfg, name)
        if shape == ():
            out[name] = jnp.float32(1.0)
        else:
            out[name] = jnp.eye(shape[0], dtype=jnp.float32)
    return out


# ---------------------------------------------------------------------------
# Core ops
# ---------------------------------------------------------------------------


def rmsnorm(x: jnp.ndarray, g: jnp.ndarray) -> jnp.ndarray:
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + EPS) * g


def rope_angles(cfg: ModelConfig, positions: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """cos/sin tables [**pos-shape**, d_head/2] for rotary embedding."""
    dh = cfg.d_head
    inv_freq = 1.0 / (cfg.rope_theta ** (jnp.arange(0, dh, 2, dtype=jnp.float32) / dh))
    ang = positions[..., None].astype(jnp.float32) * inv_freq
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x [..., T, H, dh]; cos/sin broadcastable [..., T, 1, dh/2]."""
    x1 = x[..., 0::2]
    x2 = x[..., 1::2]
    o1 = x1 * cos - x2 * sin
    o2 = x2 * cos + x1 * sin
    return jnp.stack([o1, o2], axis=-1).reshape(x.shape)


class QLinearCtx:
    """Per-graph quantization context: mode + rotation parameter lookup.

    Modes: ``fp`` (no transform), ``w4a4`` (online rotation + per-token
    dynamic int4 activations), ``w4a16`` (online rotation only), and
    ``w4a4s`` (online rotation + **static per-tensor** int4 activations —
    SmoothQuant's original quantizer form; the ``clip_<site>`` parameter
    is reinterpreted as the fixed scale Δ calibrated offline)."""

    def __init__(self, mode: str, rots: Optional[Dict[str, jnp.ndarray]]):
        assert mode in ("fp", "w4a4", "w4a16", "w4a4s")
        self.mode = mode
        self.rots = rots or {}

    def linear(self, x2d: jnp.ndarray, ws: List[jnp.ndarray], layer: int,
               site: str) -> jnp.ndarray:
        """Rotate-quantize-matmul against the horizontal concat of `ws`.

        x2d: [N, n]. Multiple weights sharing one site (e.g. q,k,v) are
        concatenated so the activation is rotated and quantized once.
        """
        w = ws[0] if len(ws) == 1 else jnp.concatenate(ws, axis=1)
        if self.mode == "fp":
            return x2d @ w
        p = f"l{layer:02d}"
        r1 = self.rots[f"{p}.rot_{site}.r1"]
        r2 = self.rots[f"{p}.rot_{site}.r2"]
        clip = self.rots[f"{p}.clip_{site}"]
        xr = kernels.kron_rotate(x2d, r1, r2)
        if self.mode == "w4a4":
            # clip enters via pre-scaling so the clip scalar can stay a
            # runtime parameter (kernel bakes only the bit-width).
            return kernels.quant_matmul(xr * (1.0 / clip), w, bits=4) * clip
        if self.mode == "w4a4s":
            # static per-tensor: clip carries the calibrated scale Δ
            q = jnp.clip(jnp.round(xr / clip), -8.0, 7.0) * clip
            return q @ w
        return xr @ w  # w4a16: rotation online, activations full-precision


# ---------------------------------------------------------------------------
# Transformer blocks
# ---------------------------------------------------------------------------


def _attention(cfg: ModelConfig, q, k, v, mask):
    """q,k,v: [B,T,H,dh] (k/v may be [B,S,H,dh]); mask broadcast [T,S]."""
    dh = cfg.d_head
    logits = jnp.einsum("bthd,bshd->bhts", q, k) / math.sqrt(dh)
    logits = jnp.where(mask, logits, -1e9)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhts,bshd->bthd", probs, v)


def _mlp(ctx: QLinearCtx, p: Dict[str, jnp.ndarray], x2d: jnp.ndarray,
         layer: int, prefix: str) -> jnp.ndarray:
    gu = ctx.linear(x2d, [p[f"{prefix}.wg"], p[f"{prefix}.wu"]], layer, "mlp")
    ff = p[f"{prefix}.wg"].shape[1]
    g, u = gu[:, :ff], gu[:, ff:]
    h = jax.nn.silu(g) * u
    return ctx.linear(h, [p[f"{prefix}.wd"]], layer, "down")


def _moe_mlp(cfg: ModelConfig, ctx: QLinearCtx, p: Dict[str, jnp.ndarray],
             x2d: jnp.ndarray, layer: int) -> jnp.ndarray:
    """Dense-compute top-k routed MoE (experts are small; routing weights
    zero out non-selected experts, matching Mixtral semantics)."""
    pre = f"l{layer:02d}"
    router_logits = x2d @ p[f"{pre}.router"]              # [N, E]
    # top-k via iterated argmax: xla_extension 0.5.1's HLO text parser
    # rejects the `topk(..., largest=true)` op jax.lax.top_k lowers to.
    remaining = router_logits
    tops = []   # ([N] values, [N,E] one-hots)
    for _ in range(cfg.top_k):
        idx = jnp.argmax(remaining, axis=-1)              # [N]
        oh = jax.nn.one_hot(idx, cfg.n_experts, dtype=x2d.dtype)
        val = jnp.sum(remaining * oh, axis=-1)            # [N]
        tops.append((val, oh))
        remaining = remaining - oh * 1e9
    topv = jnp.stack([v for v, _ in tops], axis=-1)       # [N, k]
    gate = jax.nn.softmax(topv, axis=-1)                  # [N, k]
    onehot = jnp.stack([oh for _, oh in tops], axis=1)    # [N, k, E]
    weights = jnp.einsum("nk,nke->ne", gate, onehot)       # [N, E]
    out = jnp.zeros_like(x2d)
    for e in range(cfg.n_experts):
        y = _mlp(ctx, p, x2d, layer, f"{pre}.x{e}")
        out = out + y * weights[:, e:e + 1]
    return out


def _block_score(cfg: ModelConfig, ctx: QLinearCtx, p, x, layer: int, cos, sin, mask):
    """Full-sequence block used by score/prefill. x: [B,T,d]."""
    b, t, d = x.shape
    pre = f"l{layer:02d}"
    h = rmsnorm(x, p[f"{pre}.an"]).reshape(b * t, d)
    qkv = ctx.linear(h, [p[f"{pre}.wq"], p[f"{pre}.wk"], p[f"{pre}.wv"]], layer, "qkv")
    q, k, v = jnp.split(qkv, 3, axis=1)
    q = q.reshape(b, t, cfg.n_heads, cfg.d_head)
    k = k.reshape(b, t, cfg.n_heads, cfg.d_head)
    v = v.reshape(b, t, cfg.n_heads, cfg.d_head)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    att = _attention(cfg, q, k, v, mask).reshape(b * t, d)
    x = x + ctx.linear(att, [p[f"{pre}.wo"]], layer, "o").reshape(b, t, d)
    h2 = rmsnorm(x, p[f"{pre}.mn"]).reshape(b * t, d)
    if cfg.is_moe:
        y = _moe_mlp(cfg, ctx, p, h2, layer)
    else:
        y = _mlp(ctx, p, h2, layer, pre)
    return x + y.reshape(b, t, d), k, v


def _assemble(cfg: ModelConfig, mode: str, flat: List[jnp.ndarray]) -> Tuple[dict, QLinearCtx]:
    names = param_layout(cfg, mode)
    assert len(flat) == len(names), f"expected {len(names)} params, got {len(flat)}"
    p = dict(zip(names, flat))
    rots = {k: v for k, v in p.items() if ".rot_" in k or ".clip_" in k}
    return p, QLinearCtx(mode, rots)


# ---------------------------------------------------------------------------
# Graph families
# ---------------------------------------------------------------------------


def score_graph(cfg: ModelConfig, mode: str, tokens: jnp.ndarray,
                *flat: jnp.ndarray) -> Tuple[jnp.ndarray]:
    """tokens [B,T] int32 -> logits [B,T,V]."""
    p, ctx = _assemble(cfg, mode, list(flat))
    b, t = tokens.shape
    x = p["emb.tok"][tokens]                              # [B,T,d]
    positions = jnp.arange(t)
    cos, sin = rope_angles(cfg, positions)                # [T, dh/2]
    cos = cos[None, :, None, :]
    sin = sin[None, :, None, :]
    mask = jnp.tril(jnp.ones((t, t), bool))[None, None]
    for i in range(cfg.n_layers):
        x, _, _ = _block_score(cfg, ctx, p, x, i, cos, sin, mask)
    x = rmsnorm(x, p["out.norm"])
    logits = x.reshape(b * t, cfg.d_model) @ p["out.head"]
    return (logits.reshape(b, t, cfg.vocab_size),)


def prefill_graph(cfg: ModelConfig, mode: str, tokens: jnp.ndarray,
                  *flat: jnp.ndarray):
    """tokens [B,T] -> (logits [B,T,V], K, V) with caches [L,B,H,Tmax,dh].

    Full-sequence logits are returned (not just the last position) so the
    coordinator can serve mixed prompt lengths inside one padded batch: it
    reads each request's logits at its true last prompt index.
    """
    p, ctx = _assemble(cfg, mode, list(flat))
    b, t = tokens.shape
    tmax = cfg.max_seq
    x = p["emb.tok"][tokens]
    positions = jnp.arange(t)
    cos, sin = rope_angles(cfg, positions)
    cos = cos[None, :, None, :]
    sin = sin[None, :, None, :]
    mask = jnp.tril(jnp.ones((t, t), bool))[None, None]
    kc = jnp.zeros((cfg.n_layers, b, cfg.n_heads, tmax, cfg.d_head), jnp.float32)
    vc = jnp.zeros_like(kc)
    for i in range(cfg.n_layers):
        x, k, v = _block_score(cfg, ctx, p, x, i, cos, sin, mask)
        kc = kc.at[i, :, :, :t, :].set(jnp.swapaxes(k, 1, 2))
        vc = vc.at[i, :, :, :t, :].set(jnp.swapaxes(v, 1, 2))
    x = rmsnorm(x, p["out.norm"])
    logits = x.reshape(b * t, cfg.d_model) @ p["out.head"]
    return logits.reshape(b, t, cfg.vocab_size), kc, vc


def decode_graph(cfg: ModelConfig, mode: str, token: jnp.ndarray,
                 pos: jnp.ndarray, kc: jnp.ndarray, vc: jnp.ndarray,
                 *flat: jnp.ndarray):
    """One decode step. token [B] int32, pos [B] int32 (per-slot index of
    the new token — continuous batching runs ragged sequences), caches
    [L,B,H,Tmax,dh] -> (logits [B,V], K', V')."""
    p, ctx = _assemble(cfg, mode, list(flat))
    b = token.shape[0]
    tmax = cfg.max_seq
    x = p["emb.tok"][token]                               # [B,d]
    cos, sin = rope_angles(cfg, pos)                      # [B, dh/2]
    cos1 = cos[:, None, None, :]                          # [B,1,1,dh/2]
    sin1 = sin[:, None, None, :]
    # per-slot causal mask over cache slots: [B,1,1,Tmax]
    slot_mask = (jnp.arange(tmax)[None, :] <= pos[:, None])[:, None, None, :]
    # one-hot cache write position per slot: [B,Tmax]
    write = jax.nn.one_hot(pos, tmax, dtype=jnp.float32)
    for i in range(cfg.n_layers):
        pre = f"l{i:02d}"
        h = rmsnorm(x, p[f"{pre}.an"])
        qkv = ctx.linear(h, [p[f"{pre}.wq"], p[f"{pre}.wk"], p[f"{pre}.wv"]], i, "qkv")
        q, k, v = jnp.split(qkv, 3, axis=1)
        q = q.reshape(b, 1, cfg.n_heads, cfg.d_head)
        k = k.reshape(b, 1, cfg.n_heads, cfg.d_head)
        v = v.reshape(b, 1, cfg.n_heads, cfg.d_head)
        q = apply_rope(q, cos1, sin1)
        k = apply_rope(k, cos1, sin1)
        # write new k/v into each slot's cache row at its own position:
        # cache[i, b, h, t, d] = old*(1-write[b,t]) + new[b,h,d]*write[b,t]
        knew = jnp.swapaxes(k, 1, 2)                      # [B,H,1,dh]
        vnew = jnp.swapaxes(v, 1, 2)
        wmask = write[None, :, None, :, None]             # [1,B,1,Tmax,1]
        kc = kc.at[i].set(kc[i] * (1.0 - wmask[0]) + knew * wmask[0])
        vc = vc.at[i].set(vc[i] * (1.0 - wmask[0]) + vnew * wmask[0])
        kall = jnp.swapaxes(kc[i], 1, 2)                  # [B,Tmax,H,dh]
        vall = jnp.swapaxes(vc[i], 1, 2)
        att = _attention(cfg, q, kall, vall, slot_mask)   # [B,1,H,dh]
        att = att.reshape(b, cfg.d_model)
        x = x + ctx.linear(att, [p[f"{pre}.wo"]], i, "o")
        h2 = rmsnorm(x, p[f"{pre}.mn"])
        if cfg.is_moe:
            y = _moe_mlp(cfg, ctx, p, h2, i)
        else:
            y = _mlp(ctx, p, h2, i, pre)
        x = x + y
    x = rmsnorm(x, p["out.norm"])
    logits = x @ p["out.head"]
    return logits, kc, vc


# ---------------------------------------------------------------------------
# Training loss (build-time only)
# ---------------------------------------------------------------------------


def lm_loss(cfg: ModelConfig, params: Dict[str, jnp.ndarray],
            tokens: jnp.ndarray) -> jnp.ndarray:
    """Next-token cross-entropy over tokens [B,T] (fp graph)."""
    flat = [params[n] for n in param_layout(cfg, "fp")]
    (logits,) = score_graph(cfg, "fp", tokens, *flat)
    logp = jax.nn.log_softmax(logits[:, :-1, :], axis=-1)
    tgt = tokens[:, 1:]
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)
