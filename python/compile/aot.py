"""AOT lowering: JAX graphs -> HLO text artifacts for the Rust runtime.

HLO **text** (not a serialized HloModuleProto) is the interchange format:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (the version behind the published `xla` crate) rejects; the text
parser reassigns ids and round-trips cleanly. See /opt/xla-example/README.

For every artifact we also emit a `.layout.json` describing the exact
positional input list (data inputs first, then parameters in
`model.param_layout` order) and the output arity, plus a global
`manifest.json` the Rust side uses as its single source of truth for model
configs and artifact paths.

Run once via `make artifacts`; the Rust binary is self-contained afterwards.
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Callable, List

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import kernels
from . import model as M

SCORE_B = 4          # fixed batch of the score graph
SERVE_CFG = "sq-m"   # the serving / Fig-3 model
SERVE_BATCHES = [1, 4, 16, 32]
LONG_B, LONG_T = 2, 448  # few-shot (MMLU) scoring graph, sq-m only
KBENCH_T, KBENCH_N = 128, 256  # kernel micro-bench shape


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    # print_large_constants is load-bearing: the default printer elides
    # constants over ~1k elements as `{...}`, which xla_extension 0.5.1's
    # text parser accepts SILENTLY and fills with garbage — e.g. the RoPE
    # cos/sin tables of any model with d_head > 16 came back corrupted.
    return comp.as_hlo_text(print_large_constants=True)


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _param_specs(cfg: M.ModelConfig, mode: str) -> List[jax.ShapeDtypeStruct]:
    return [_spec(M.param_shape(cfg, n)) for n in M.param_layout(cfg, mode)]


def _layout_entry(name: str, spec: jax.ShapeDtypeStruct) -> dict:
    return {"name": name, "shape": [int(d) for d in spec.shape],
            "dtype": "i32" if spec.dtype == jnp.int32 else "f32"}


def lower_artifact(out_dir: str, fname: str, fn: Callable,
                   data_specs: List[tuple], cfg: M.ModelConfig, mode: str,
                   n_outputs: int, meta: dict) -> dict:
    """Lower `fn(data..., *params)` and write .hlo.txt + .layout.json."""
    specs = [s for _, s in data_specs] + _param_specs(cfg, mode)
    t0 = time.time()
    lowered = jax.jit(fn).lower(*specs)
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, f"{fname}.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    layout = {
        "inputs": ([_layout_entry(n, s) for n, s in data_specs]
                   + [_layout_entry(n, s) for n, s in
                      zip(M.param_layout(cfg, mode), _param_specs(cfg, mode))]),
        "n_outputs": n_outputs,
        **meta,
    }
    with open(os.path.join(out_dir, f"{fname}.layout.json"), "w") as f:
        json.dump(layout, f)
    print(f"  {fname}: {len(text) // 1024} KiB HLO ({time.time() - t0:.1f}s)",
          flush=True)
    return {"file": f"{fname}.hlo.txt", "layout": f"{fname}.layout.json", **meta}


def lower_config(cfg: M.ModelConfig, out_dir: str, serve: bool) -> List[dict]:
    arts = []
    t, tmax = cfg.score_seq, cfg.max_seq

    for mode in ("fp", "w4a4", "w4a16", "w4a4s"):
        def score_fn(tokens, *flat, _mode=mode):
            return M.score_graph(cfg, _mode, tokens, *flat)

        arts.append(lower_artifact(
            out_dir, f"{cfg.name}_score_{mode}_b{SCORE_B}", score_fn,
            [("in.tokens", _spec((SCORE_B, t), jnp.int32))], cfg, mode, 1,
            {"config": cfg.name, "graph": "score", "mode": mode,
             "batch": SCORE_B, "seq": t}))
        if serve:  # the MMLU (Vicuna) model also gets a long-context scorer
            arts.append(lower_artifact(
                out_dir, f"{cfg.name}_scorelong_{mode}_b{LONG_B}", score_fn,
                [("in.tokens", _spec((LONG_B, LONG_T), jnp.int32))], cfg,
                mode, 1,
                {"config": cfg.name, "graph": "scorelong", "mode": mode,
                 "batch": LONG_B, "seq": LONG_T}))

    if serve:
        for mode in ("fp", "w4a4"):
            for b in SERVE_BATCHES:
                def prefill_fn(tokens, *flat, _mode=mode):
                    return M.prefill_graph(cfg, _mode, tokens, *flat)

                def decode_fn(token, pos, kc, vc, *flat, _mode=mode):
                    return M.decode_graph(cfg, _mode, token, pos, kc, vc, *flat)

                kv = _spec((cfg.n_layers, b, cfg.n_heads, tmax, cfg.d_head))
                arts.append(lower_artifact(
                    out_dir, f"{cfg.name}_prefill_{mode}_b{b}", prefill_fn,
                    [("in.tokens", _spec((b, t), jnp.int32))], cfg, mode, 3,
                    {"config": cfg.name, "graph": "prefill", "mode": mode,
                     "batch": b, "seq": t}))
                arts.append(lower_artifact(
                    out_dir, f"{cfg.name}_decode_{mode}_b{b}", decode_fn,
                    [("in.token", _spec((b,), jnp.int32)),
                     ("in.pos", _spec((b,), jnp.int32)),
                     ("in.kcache", kv), ("in.vcache", kv)],
                    cfg, mode, 3,
                    {"config": cfg.name, "graph": "decode", "mode": mode,
                     "batch": b, "seq": tmax}))
    return arts


def lower_kernel_benches(out_dir: str) -> List[dict]:
    """Standalone L1 kernel graphs for Rust-side micro-benchmarks."""
    arts = []
    t, n = KBENCH_T, KBENCH_N
    n1, n2 = M.kron_factor(n)
    cases = [
        ("kernel_kron", lambda x, r1, r2: (kernels.kron_rotate(x, r1, r2),),
         [("in.x", _spec((t, n))), ("in.r1", _spec((n1, n1))),
          ("in.r2", _spec((n2, n2)))]),
        ("kernel_dense_rotate", lambda x, r: (x @ r,),
         [("in.x", _spec((t, n))), ("in.r", _spec((n, n)))]),
        ("kernel_qmm", lambda x, w: (kernels.quant_matmul(x, w, bits=4),),
         [("in.x", _spec((t, n))), ("in.w", _spec((n, n)))]),
        ("kernel_mm", lambda x, w: (x @ w,),
         [("in.x", _spec((t, n))), ("in.w", _spec((n, n)))]),
        ("kernel_hadamard", lambda x: (kernels.hadamard(x),),
         [("in.x", _spec((t, n)))]),
    ]
    for name, fn, data in cases:
        t0 = time.time()
        lowered = jax.jit(fn).lower(*[s for _, s in data])
        text = to_hlo_text(lowered)
        with open(os.path.join(out_dir, f"{name}.hlo.txt"), "w") as f:
            f.write(text)
        layout = {"inputs": [_layout_entry(nm, s) for nm, s in data],
                  "n_outputs": 1, "graph": name}
        with open(os.path.join(out_dir, f"{name}.layout.json"), "w") as f:
            json.dump(layout, f)
        arts.append({"file": f"{name}.hlo.txt", "layout": f"{name}.layout.json",
                     "graph": name, "config": None, "mode": None,
                     "batch": t, "seq": None})
        print(f"  {name}: {len(text) // 1024} KiB ({time.time() - t0:.1f}s)",
              flush=True)
    return arts


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", required=True)
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    names = (args.only.split(",") if args.only else
             ["sq-s", "sq-m", "sq-l", "sq-xl", "sq-moe"])
    artifacts: List[dict] = []
    for name in names:
        cfg = M.CONFIGS[name]
        print(f"lowering {name} ...", flush=True)
        artifacts += lower_config(cfg, args.out, serve=(name == SERVE_CFG))
    print("lowering kernel benches ...", flush=True)
    artifacts += lower_kernel_benches(args.out)

    configs = {}
    for name, cfg in M.CONFIGS.items():
        n1d, n2d = M.kron_factor(cfg.d_model)
        n1f, n2f = M.kron_factor(cfg.d_ff)
        configs[name] = {
            "d_model": cfg.d_model, "n_layers": cfg.n_layers,
            "n_heads": cfg.n_heads, "d_ff": cfg.d_ff,
            "vocab_size": cfg.vocab_size, "max_seq": cfg.max_seq,
            "score_seq": cfg.score_seq, "rope_theta": cfg.rope_theta,
            "n_experts": cfg.n_experts, "top_k": cfg.top_k,
            "kron_d": [n1d, n2d], "kron_ff": [n1f, n2f],
            # chat shares sq-m graphs
            "artifact_config": "sq-m" if name == "sq-m-chat" else name,
        }
    manifest = {
        "version": 1, "score_batch": SCORE_B, "serve_config": SERVE_CFG,
        "serve_batches": SERVE_BATCHES, "configs": configs,
        "long_batch": LONG_B, "long_seq": LONG_T,
        "artifacts": artifacts,
        "kbench": {"t": KBENCH_T, "n": KBENCH_N},
    }
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote manifest with {len(artifacts)} artifacts")


if __name__ == "__main__":
    main()
