"""Synthetic-world data generation for the SingleQuant reproduction.

The paper evaluates on WikiText-2 / C4 perplexity, six zero-shot QA tasks,
MMLU, and instruction-tuned (Vicuna) models. None of those corpora are
available in this offline environment, so we build a deterministic synthetic
world that preserves the *measurement structure* of the paper's evaluation
(see DESIGN.md §Substitutions):

* a knowledge base of entities with attributes (color, city, craft, trait,
  animal, tool, number, ally),
* a low-entropy "wiki-like" corpus and a higher-entropy "web-like" corpus
  rendering those facts through sentence templates (standing in for
  WikiText-2 and C4),
* six multiple-choice QA suites mirroring ARC-E/ARC-C/HellaSwag/LAMBADA/
  PIQA/WinoGrande in format and graded difficulty,
* a four-domain MMLU-like suite with 0-shot and 5-shot variants,
* an instruction-formatted corpus for the chat (Vicuna-like) variant.

Everything is produced by `python -m compile.data --out ../artifacts/data`
at build time; the Rust side only ever reads the emitted token files and
JSON — the generators never run at inference time.

Tokenization is byte-level: ids 0..255 are raw bytes, 256=BOS, 257=EOS,
258=PAD. `VOCAB_SIZE` is padded to 260.
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Dict, List

import numpy as np

from . import sqt

BOS, EOS, PAD = 256, 257, 258
VOCAB_SIZE = 260

# ---------------------------------------------------------------------------
# Tokenizer (byte level; the Rust twin is rust/src/coordinator/tokenizer.rs)
# ---------------------------------------------------------------------------


def encode(text: str, bos: bool = False, eos: bool = False) -> List[int]:
    ids = list(text.encode("utf-8"))
    if bos:
        ids = [BOS] + ids
    if eos:
        ids = ids + [EOS]
    return ids


def decode(ids) -> str:
    return bytes(i for i in ids if i < 256).decode("utf-8", errors="replace")


# ---------------------------------------------------------------------------
# Knowledge base
# ---------------------------------------------------------------------------

_SYL_A = ["zor", "min", "tal", "ver", "bek", "lun", "dra", "pol", "sar", "nim",
          "kel", "fos", "gri", "hul", "jav", "rud"]
_SYL_B = ["ba", "ti", "ko", "ma", "re", "su", "vi", "no", "la", "du"]
_SYL_C = ["l", "n", "k", "r", "s", "x", "m", "t"]

COLORS = ["red", "blue", "green", "amber", "violet", "ivory", "teal", "black",
          "white", "copper", "silver", "crimson"]
CITIES = ["varno", "lumis", "ketra", "ostin", "perla", "quom", "rilva",
          "sunda", "tolme", "ubrik", "velda", "wistra"]
CRAFTS = ["weaving", "smithing", "carving", "glazing", "brewing", "mapping",
          "binding", "fletching"]
TRAITS = ["patient", "stubborn", "curious", "gentle", "bold", "quiet",
          "clever", "honest"]
ANIMALS = ["heron", "lynx", "otter", "falcon", "marten", "ibex", "crane",
           "badger"]
TOOLS = {  # craft -> tool (the PIQA-like procedural association)
    "weaving": "loom", "smithing": "anvil", "carving": "chisel",
    "glazing": "kiln", "brewing": "kettle", "mapping": "compass",
    "binding": "awl", "fletching": "jig",
}
MATERIALS = ["flax", "ore", "oak", "clay", "barley", "vellum", "hide", "cedar"]

N_ENTITIES = 160
N_COMMON = 48  # high-frequency entities (easy-task pool)


class World:
    """Deterministic entity/attribute knowledge base."""

    def __init__(self, seed: int = 7):
        rng = np.random.default_rng(seed)
        self.names: List[str] = []
        seen = set()
        while len(self.names) < N_ENTITIES:
            n = (rng.choice(_SYL_A) + rng.choice(_SYL_B) + rng.choice(_SYL_C))
            if n not in seen:
                seen.add(n)
                self.names.append(n)
        self.color = {n: COLORS[int(rng.integers(len(COLORS)))] for n in self.names}
        self.city = {n: CITIES[int(rng.integers(len(CITIES)))] for n in self.names}
        self.craft = {n: CRAFTS[int(rng.integers(len(CRAFTS)))] for n in self.names}
        self.trait = {n: TRAITS[int(rng.integers(len(TRAITS)))] for n in self.names}
        self.animal = {n: ANIMALS[int(rng.integers(len(ANIMALS)))] for n in self.names}
        self.number = {n: int(rng.integers(2, 60)) for n in self.names}
        self.material = {n: MATERIALS[int(rng.integers(len(MATERIALS)))] for n in self.names}
        allies = rng.permutation(N_ENTITIES)
        self.ally = {self.names[i]: self.names[int(allies[i])] for i in range(N_ENTITIES)}
        self.common = self.names[:N_COMMON]
        self.rare = self.names[N_COMMON:]

    # -- sentence renderers --------------------------------------------------
    def fact_sentences(self, n: str) -> List[str]:
        c = self
        return [
            f"the {c.craft[n]} master {n} of {c.city[n]} kept a {c.color[n]} {c.animal[n]} .",
            f"{n} was known in {c.city[n]} for being {c.trait[n]} .",
            f"every morning {n} fed the {c.color[n]} {c.animal[n]} near the gates of {c.city[n]} .",
            f"to practice {c.craft[n]} , {n} used a {TOOLS[c.craft[n]]} made of {c.material[n]} .",
            f"{n} measured {c.number[n]} units of {c.material[n]} for the guild .",
            f"the oldest friend of {n} was {c.ally[n]} , who lived in {c.city[c.ally[n]]} .",
            f"in {c.city[n]} , {n} studied the art of {c.craft[n]} for many years .",
            f"people said the {c.animal[n]} of {n} had {c.color[n]} feathers and a {c.trait[n]} keeper .",
        ]


# ---------------------------------------------------------------------------
# Corpora
# ---------------------------------------------------------------------------


def _pick_entity(world: World, rng) -> str:
    # 70% of mentions go to common entities -> frequency-graded difficulty.
    if rng.random() < 0.7:
        return world.common[int(rng.integers(len(world.common)))]
    return world.rare[int(rng.integers(len(world.rare)))]


def gen_wiki_corpus(world: World, n_sentences: int, seed: int) -> str:
    """Low-entropy factual corpus (WikiText-2 stand-in)."""
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n_sentences):
        n = _pick_entity(world, rng)
        sents = world.fact_sentences(n)
        out.append(sents[int(rng.integers(len(sents)))])
    return "\n".join(out) + "\n"


_WEB_FILLER = [
    "click here for more about {city} and its markets .",
    "top {k} facts about {craft} you should know :",
    "posted on day {k} | tags : {craft} , {city} , {animal}",
    "price of {material} rose by {k} marks in {city} .",
    "visit http://{city}.example/{name} for the full story .",
    "{k} . {name} answered : the {animal} is {color} , obviously .",
]


def gen_web_corpus(world: World, n_sentences: int, seed: int) -> str:
    """Higher-entropy noisy corpus (C4 stand-in): same facts, messier text."""
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n_sentences):
        n = _pick_entity(world, rng)
        if rng.random() < 0.55:
            sents = world.fact_sentences(n)
            out.append(sents[int(rng.integers(len(sents)))])
        else:
            t = _WEB_FILLER[int(rng.integers(len(_WEB_FILLER)))]
            out.append(t.format(
                city=world.city[n], craft=world.craft[n], animal=world.animal[n],
                color=world.color[n], material=world.material[n], name=n,
                k=int(rng.integers(2, 99))))
    return "\n".join(out) + "\n"


def gen_chat_corpus(world: World, n_items: int, seed: int) -> str:
    """Instruction-formatted corpus for the Vicuna-like chat finetune."""
    rng = np.random.default_rng(seed)
    out = []
    qa = [
        ("what color is the {animal} of {name} ?", "{color}"),
        ("where does {name} live ?", "{city}"),
        ("what craft does {name} practice ?", "{craft}"),
        ("what tool does {name} use ?", "{tool}"),
        ("who is the oldest friend of {name} ?", "{ally}"),
        ("how many units of {material} did {name} measure ?", "{number}"),
    ]
    for _ in range(n_items):
        n = _pick_entity(world, rng)
        q, a = qa[int(rng.integers(len(qa)))]
        fmt = dict(name=n, color=world.color[n], city=world.city[n],
                   craft=world.craft[n], tool=TOOLS[world.craft[n]],
                   ally=world.ally[n], material=world.material[n],
                   number=world.number[n], animal=world.animal[n])
        out.append(f"question : {q.format(**fmt)}\nanswer : {a.format(**fmt)}\n")
    return "\n".join(out)


# ---------------------------------------------------------------------------
# Zero-shot QA suites (six tasks; ARC-E/ARC-C/HellaSwag/LAMBADA/PIQA/WinoGrande
# stand-ins, graded by entity frequency and hop count)
# ---------------------------------------------------------------------------


def _mc_item(context: str, options: List[str], answer: int) -> dict:
    return {"context": context, "options": options, "answer": answer}


def _distract(rng, pool: List[str], correct: str, k: int) -> List[str]:
    cands = [p for p in pool if p != correct]
    idx = rng.permutation(len(cands))[: k]
    return [cands[int(i)] for i in idx]


def gen_tasks(world: World, n_per_task: int, seed: int) -> Dict[str, List[dict]]:
    rng = np.random.default_rng(seed)
    tasks: Dict[str, List[dict]] = {k: [] for k in
                                    ["facts_easy", "facts_hard", "continuation",
                                     "lastword", "procedure", "pronoun"]}
    for _ in range(n_per_task):
        # facts_easy (ARC-E-like): common entity, one-hop attribute.
        n = world.common[int(rng.integers(len(world.common)))]
        correct = world.color[n]
        opts = [correct] + _distract(rng, COLORS, correct, 3)
        perm = rng.permutation(4)
        tasks["facts_easy"].append(_mc_item(
            f"the {world.animal[n]} kept by {n} was",
            [" " + opts[int(i)] for i in perm], int(np.argwhere(perm == 0)[0][0])))

        # facts_hard (ARC-C-like): rare entity, two-hop (city+craft -> animal color).
        n = world.rare[int(rng.integers(len(world.rare)))]
        correct = world.animal[n]
        opts = [correct] + _distract(rng, ANIMALS, correct, 3)
        perm = rng.permutation(4)
        tasks["facts_hard"].append(_mc_item(
            f"the {world.craft[n]} master {n} of {world.city[n]} kept a {world.color[n]}",
            [" " + opts[int(i)] for i in perm], int(np.argwhere(perm == 0)[0][0])))

        # continuation (HellaSwag-like): pick the right sentence ending.
        n = _pick_entity(world, rng)
        good = f" near the gates of {world.city[n]} ."
        bads = [f" near the gates of {c} ." for c in _distract(rng, CITIES, world.city[n], 3)]
        opts4 = [good] + bads
        perm = rng.permutation(4)
        tasks["continuation"].append(_mc_item(
            f"every morning {n} fed the {world.color[n]} {world.animal[n]}",
            [opts4[int(i)] for i in perm], int(np.argwhere(perm == 0)[0][0])))

        # lastword (LAMBADA-like): long context, predict the final word.
        n = _pick_entity(world, rng)
        ctx = (f"{n} was known in {world.city[n]} for being {world.trait[n]} . "
               f"in {world.city[n]} , {n} studied the art of {world.craft[n]} for many years . "
               f"every morning {n} fed the {world.color[n]}")
        correct = world.animal[n]
        opts = [correct] + _distract(rng, ANIMALS, correct, 3)
        perm = rng.permutation(4)
        tasks["lastword"].append(_mc_item(
            ctx, [" " + opts[int(i)] for i in perm], int(np.argwhere(perm == 0)[0][0])))

        # procedure (PIQA-like): craft -> tool.
        n = _pick_entity(world, rng)
        correct = TOOLS[world.craft[n]]
        pool = list(TOOLS.values())
        opts = [correct] + _distract(rng, pool, correct, 3)
        perm = rng.permutation(4)
        tasks["procedure"].append(_mc_item(
            f"to practice {world.craft[n]} , {n} used a",
            [" " + opts[int(i)] for i in perm], int(np.argwhere(perm == 0)[0][0])))

        # pronoun (WinoGrande-like): 2 options, trait binding.
        a = _pick_entity(world, rng)
        b = world.ally[a]
        opts2 = [a, b]
        perm = rng.permutation(2)
        tasks["pronoun"].append(_mc_item(
            f"{a} gave the {world.animal[a]} to {b} because the keeper known for being "
            f"{world.trait[a]} was", [" " + opts2[int(i)] for i in perm],
            int(np.argwhere(perm == 0)[0][0])))
    return tasks


# ---------------------------------------------------------------------------
# MMLU-like four-domain suite
# ---------------------------------------------------------------------------


def gen_mmlu(world: World, n_per_domain: int, seed: int) -> dict:
    """Four domains (stem / hums / social / others) with 0- and 5-shot forms.

    Items follow the lm-eval MMLU convention: `question\\nanswer:` contexts
    with single-token-ish answers, plus 5 exemplar Q/A pairs for few-shot.
    """
    rng = np.random.default_rng(seed)
    domains = {"stem": [], "hums": [], "social": [], "others": []}

    def ent():
        return _pick_entity(world, rng)

    for _ in range(n_per_domain):
        n = ent()
        correct = str(world.number[n])
        opts = [correct] + [str(x) for x in
                            rng.choice([k for k in range(2, 60) if str(k) != correct],
                                       size=3, replace=False)]
        perm = rng.permutation(4)
        domains["stem"].append(_mc_item(
            f"question : how many units of {world.material[n]} did {n} measure ?\nanswer :",
            [" " + opts[int(i)] for i in perm], int(np.argwhere(perm == 0)[0][0])))

        n = ent()
        correct = world.craft[n]
        opts = [correct] + _distract(rng, CRAFTS, correct, 3)
        perm = rng.permutation(4)
        domains["hums"].append(_mc_item(
            f"question : which art did {n} study in {world.city[n]} ?\nanswer :",
            [" " + opts[int(i)] for i in perm], int(np.argwhere(perm == 0)[0][0])))

        n = ent()
        correct = world.ally[n]
        opts = [correct] + _distract(rng, world.names, correct, 3)
        perm = rng.permutation(4)
        domains["social"].append(_mc_item(
            f"question : who is the oldest friend of {n} ?\nanswer :",
            [" " + opts[int(i)] for i in perm], int(np.argwhere(perm == 0)[0][0])))

        n = ent()
        correct = world.city[n]
        opts = [correct] + _distract(rng, CITIES, correct, 3)
        perm = rng.permutation(4)
        domains["others"].append(_mc_item(
            f"question : where did {n} live ?\nanswer :",
            [" " + opts[int(i)] for i in perm], int(np.argwhere(perm == 0)[0][0])))

    # 5-shot exemplar prefixes (one per domain, fixed across items).
    shots = {}
    qa = {
        "stem": lambda n: (f"question : how many units of {world.material[n]} did {n} measure ?",
                           f" {world.number[n]}"),
        "hums": lambda n: (f"question : which art did {n} study in {world.city[n]} ?",
                           f" {world.craft[n]}"),
        "social": lambda n: (f"question : who is the oldest friend of {n} ?",
                             f" {world.ally[n]}"),
        "others": lambda n: (f"question : where did {n} live ?", f" {world.city[n]}"),
    }
    for dom in domains:
        parts = []
        for _ in range(5):
            n = world.common[int(rng.integers(len(world.common)))]
            q, a = qa[dom](n)
            parts.append(f"{q}\nanswer :{a}\n")
        shots[dom] = "\n".join(parts) + "\n"
    return {"domains": domains, "shots": shots}


# ---------------------------------------------------------------------------
# Emission
# ---------------------------------------------------------------------------


def tokens_u16(text: str) -> np.ndarray:
    return np.array(encode(text), dtype=np.uint16)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", required=True)
    ap.add_argument("--fast", action="store_true", help="small outputs for CI")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    world = World(seed=7)
    n_train = 30_000 if not args.fast else 2_000
    n_eval = 2_600 if not args.fast else 300
    n_task = 200 if not args.fast else 24
    n_mmlu = 120 if not args.fast else 16

    corpora = {
        "wiki_train": gen_wiki_corpus(world, n_train, seed=11),
        "wiki_eval": gen_wiki_corpus(world, n_eval, seed=12),
        "web_train": gen_web_corpus(world, n_train, seed=13),
        "web_eval": gen_web_corpus(world, n_eval, seed=14),
        "chat_train": gen_chat_corpus(world, n_train // 3, seed=15),
    }
    for name, text in corpora.items():
        toks = tokens_u16(text)
        sqt.save(os.path.join(args.out, f"corpus_{name}.sqt"),
                 {"tokens": toks}, {"kind": "corpus", "name": name,
                                    "n_tokens": int(toks.size)})
        print(f"corpus {name}: {toks.size} tokens")

    tasks = gen_tasks(world, n_task, seed=21)
    with open(os.path.join(args.out, "tasks.json"), "w") as f:
        json.dump({"tasks": tasks}, f)
    print(f"tasks: {sum(len(v) for v in tasks.values())} items")

    mmlu = gen_mmlu(world, n_mmlu, seed=22)
    with open(os.path.join(args.out, "mmlu.json"), "w") as f:
        json.dump(mmlu, f)
    print(f"mmlu: {sum(len(v) for v in mmlu['domains'].values())} items")


if __name__ == "__main__":
    main()
