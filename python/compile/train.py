"""Build-time pretraining of the model zoo on the synthetic corpora.

Runs once under `make artifacts`; produces `artifacts/ckpt/<name>.sqt`
checkpoints the Rust pipeline quantizes and serves. Python never runs at
inference time.

Two deliberate choices mirror the paper's experimental conditions:

1. **Outlier folding.** Real LLMs exhibit massive (MO) and normal (NO)
   activation outliers — the paper's entire subject. Models this small do
   not reliably develop them in a few hundred steps, so after training we
   apply a *function-preserving* re-parameterization: a long-tailed
   per-channel scale `s` is folded into each RMSNorm gain (γ ← γ·s) with
   the inverse folded into the consuming linear's input rows (W ← W/s), and
   similarly on the MLP hidden axis via the `wu`/`wd` pair (exact because
   h = silu(g)·u is linear in u). The network function is bit-identical in
   fp, but the activations seen by every quantized linear now carry a few
   ~10–30× massive-outlier channels plus a log-normal spread of normal
   outliers — exactly the structure ART and URT target. See DESIGN.md
   §Substitutions.

2. **Adam is hand-rolled** (optax is unavailable offline).
"""
from __future__ import annotations

import argparse
import math
import os
import time
import zlib
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import model as M
from . import sqt

# Per-config training schedule (steps, batch, lr), sized for 1 CPU core.
SCHEDULE: Dict[str, Tuple[int, int, float]] = {
    "sq-xs": (120, 8, 3e-3),
    "sq-s": (420, 8, 3e-3),
    "sq-m": (420, 8, 2.5e-3),
    "sq-l": (360, 8, 2e-3),
    "sq-xl": (300, 8, 2e-3),
    "sq-moe": (360, 8, 2.5e-3),
    "sq-m-chat": (160, 8, 1e-3),  # finetune from sq-m
}

SEQ = 96  # == score_seq


# ---------------------------------------------------------------------------
# Data batching
# ---------------------------------------------------------------------------


def load_corpus(data_dir: str, name: str) -> np.ndarray:
    tensors, _ = sqt.load(os.path.join(data_dir, f"corpus_{name}.sqt"))
    return tensors["tokens"].astype(np.int32)


class Batcher:
    """Random fixed-length windows over a 60/40 wiki/web token mix."""

    def __init__(self, streams, weights, seed: int):
        self.streams = streams
        self.weights = np.asarray(weights, np.float64) / np.sum(weights)
        self.rng = np.random.default_rng(seed)

    def batch(self, bsz: int, seq: int) -> np.ndarray:
        out = np.empty((bsz, seq), np.int32)
        for i in range(bsz):
            s = self.streams[self.rng.choice(len(self.streams), p=self.weights)]
            start = int(self.rng.integers(0, len(s) - seq - 1))
            out[i] = s[start:start + seq]
        return out


# ---------------------------------------------------------------------------
# Adam
# ---------------------------------------------------------------------------


def adam_init(params):
    zeros = {k: jnp.zeros_like(v) for k, v in params.items()}
    return {"m": zeros, "v": {k: jnp.zeros_like(v) for k, v in params.items()},
            "t": jnp.int32(0)}


def make_update(cfg: M.ModelConfig, base_lr: float, total_steps: int):
    warmup = max(10, total_steps // 20)

    def lr_at(t):
        warm = base_lr * t / warmup
        prog = jnp.clip((t - warmup) / jnp.maximum(total_steps - warmup, 1), 0.0, 1.0)
        cos = base_lr * 0.5 * (1.0 + jnp.cos(math.pi * prog))
        return jnp.where(t < warmup, warm, cos)

    @jax.jit
    def update(params, opt, tokens):
        loss, grads = jax.value_and_grad(
            lambda p: M.lm_loss(cfg, p, tokens))(params)
        t = opt["t"] + 1
        lr = lr_at(t.astype(jnp.float32))
        b1, b2, eps = 0.9, 0.98, 1e-8
        new_m, new_v, new_p = {}, {}, {}
        for k in params:
            g = grads[k]
            m = b1 * opt["m"][k] + (1 - b1) * g
            v = b2 * opt["v"][k] + (1 - b2) * g * g
            mhat = m / (1 - b1 ** t.astype(jnp.float32))
            vhat = v / (1 - b2 ** t.astype(jnp.float32))
            new_p[k] = params[k] - lr * mhat / (jnp.sqrt(vhat) + eps)
            new_m[k] = m
            new_v[k] = v
        return new_p, {"m": new_m, "v": new_v, "t": t}, loss

    return update


# ---------------------------------------------------------------------------
# Outlier folding (function-preserving)
# ---------------------------------------------------------------------------


def outlier_scale(rng, n: int, n_massive: int = 3, mo_lo: float = 10.0,
                  mo_hi: float = 28.0, no_sigma: float = 0.45) -> np.ndarray:
    """Long-tailed per-channel scale: log-normal body (NO) + a few MO spikes.

    Magnitudes are chosen so that (i) per-token dynamic int4 without any
    transform is badly outlier-dominated, (ii) orthogonal mixing flattens
    the spikes into a benign ~(MO/√n)× carpet, and (iii) a static
    per-tensor activation quantizer (SmoothQuant's original form) is
    catastrophically range-starved — the Table 1 regime. Note real
    *massive* activations are also token-sparse, which a function-
    preserving re-parameterization cannot express; see DESIGN.md
    §Substitutions for why channel-persistent outliers preserve the
    relevant method ordering."""
    s = np.exp(rng.normal(0.0, no_sigma, size=n)).astype(np.float32)
    idx = rng.choice(n, size=min(n_massive, n), replace=False)
    s[idx] = rng.uniform(mo_lo, mo_hi, size=len(idx)).astype(np.float32)
    return s


def fold_outliers(cfg: M.ModelConfig, params: Dict[str, jnp.ndarray],
                  seed: int = 1234) -> Dict[str, jnp.ndarray]:
    """Fold long-tailed channel scales into norm gains / the wu·wd pair.

    Exactly preserves the network function while making post-norm and
    MLP-hidden activations carry MO/NO structure.
    """
    rng = np.random.default_rng(seed)
    p = {k: np.asarray(v) for k, v in params.items()}
    d, ff = cfg.d_model, cfg.d_ff
    for i in range(cfg.n_layers):
        pre = f"l{i:02d}"
        # attention input (qkv site)
        s = outlier_scale(rng, d)
        p[f"{pre}.an"] = p[f"{pre}.an"] * s
        for w in ("wq", "wk", "wv"):
            p[f"{pre}.{w}"] = p[f"{pre}.{w}"] / s[:, None]
        # MLP input (mlp site)
        s2 = outlier_scale(rng, d)
        p[f"{pre}.mn"] = p[f"{pre}.mn"] * s2
        if cfg.is_moe:
            for e in range(cfg.n_experts):
                for w in ("wg", "wu"):
                    p[f"{pre}.x{e}.{w}"] = p[f"{pre}.x{e}.{w}"] / s2[:, None]
            p[f"{pre}.router"] = p[f"{pre}.router"] / s2[:, None]
            # MLP hidden (down site): h = silu(g) * u is linear in u
            s3 = outlier_scale(rng, ff, n_massive=3, mo_hi=40.0)
            for e in range(cfg.n_experts):
                p[f"{pre}.x{e}.wu"] = p[f"{pre}.x{e}.wu"] * s3[None, :]
                p[f"{pre}.x{e}.wd"] = p[f"{pre}.x{e}.wd"] / s3[:, None]
        else:
            for w in ("wg", "wu"):
                p[f"{pre}.{w}"] = p[f"{pre}.{w}"] / s2[:, None]
            s3 = outlier_scale(rng, ff, n_massive=3, mo_hi=40.0)
            p[f"{pre}.wu"] = p[f"{pre}.wu"] * s3[None, :]
            p[f"{pre}.wd"] = p[f"{pre}.wd"] / s3[:, None]
    return {k: jnp.asarray(v) for k, v in p.items()}


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------


def train_one(name: str, data_dir: str, out_dir: str, fast: bool,
              init_from: str | None = None) -> None:
    cfg = M.CONFIGS[name]
    steps, bsz, lr = SCHEDULE[name]
    if fast:
        steps = max(20, steps // 10)
    if name == "sq-m-chat":
        streams = [load_corpus(data_dir, "chat_train"),
                   load_corpus(data_dir, "wiki_train")]
        weights = [0.8, 0.2]
    else:
        streams = [load_corpus(data_dir, "wiki_train"),
                   load_corpus(data_dir, "web_train")]
        weights = [0.6, 0.4]
    batcher = Batcher(streams, weights, seed=zlib.crc32(name.encode()) % (2 ** 31))

    if init_from:
        tensors, _ = sqt.load(init_from)
        params = {k: jnp.asarray(v) for k, v in tensors.items()}
    else:
        params = M.init_params(cfg, seed=42)
    opt = adam_init(params)
    update = make_update(cfg, lr, steps)

    t0 = time.time()
    loss = float("nan")
    for step in range(steps):
        tokens = jnp.asarray(batcher.batch(bsz, SEQ))
        params, opt, loss = update(params, opt, tokens)
        if step % 50 == 0 or step == steps - 1:
            print(f"[{name}] step {step:4d}/{steps} loss {float(loss):.4f} "
                  f"({time.time() - t0:.0f}s)", flush=True)

    if init_from is None:
        # Checkpoints are folded exactly once; finetuned variants inherit the
        # (function-preserving) outlier structure from their base model.
        params = fold_outliers(cfg, params, seed=1234)
    meta = {
        "config": name, "d_model": cfg.d_model, "n_layers": cfg.n_layers,
        "n_heads": cfg.n_heads, "d_ff": cfg.d_ff, "vocab_size": cfg.vocab_size,
        "max_seq": cfg.max_seq, "score_seq": cfg.score_seq,
        "rope_theta": cfg.rope_theta, "n_experts": cfg.n_experts,
        "top_k": cfg.top_k, "train_steps": steps,
        "final_loss": float(loss),
    }
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{name}.sqt")
    sqt.save(path, {k: np.asarray(v) for k, v in params.items()}, meta)
    print(f"[{name}] saved {path} (final loss {float(loss):.4f})")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", required=True)
    ap.add_argument("--data", default=None)
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--only", default=None, help="comma-separated config names")
    args = ap.parse_args()
    data_dir = args.data or os.path.join(os.path.dirname(args.out), "data")

    names = (args.only.split(",") if args.only else
             ["sq-s", "sq-m", "sq-l", "sq-xl", "sq-moe", "sq-m-chat"])
    for name in names:
        init = None
        if name == "sq-m-chat":
            base = os.path.join(args.out, "sq-m.sqt")
            init = base if os.path.exists(base) else None
        train_one(name, data_dir, args.out, args.fast, init_from=init)


if __name__ == "__main__":
    main()
