"""Layer-1 Pallas kernels: the W4A4 inference hot path.

Four kernels implement the paper's compute primitives:

* :func:`quant_matmul`  — fused per-token int-`b` activation fake-quant + GEMM
  (the W4A4 GEMM of Fig. 3).
* :func:`kron_rotate`   — the Kronecker rotation ``x (R1 ⊗ R2)`` in the
  two-sided small-GEMM form of Eq. 31 (the O(n^{3/2}) online transform).
* :func:`hadamard`      — blocked fast Walsh–Hadamard transform (QuaRot
  baseline's online rotation).
* :func:`rtn_quant_weight` — per-output-channel RTN weight fake-quantizer.

All kernels run under ``interpret=True`` (mandatory on the CPU PJRT plugin —
real TPU lowering emits Mosaic custom-calls the CPU client cannot execute).
The BlockSpecs are nevertheless written for the TPU memory system: token
tiles of ≤128 rows stream HBM→VMEM while rotation factors / weight tiles
stay VMEM-resident; matmuls are shaped for the 128×128 MXU. DESIGN.md
§Hardware-Adaptation describes the GPU→TPU mapping; EXPERIMENTS.md §Perf
carries the VMEM/MXU estimates.

Correctness oracles live in :mod:`compile.kernels.ref`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

INTERPRET = True  # CPU PJRT cannot run Mosaic custom-calls; see module doc.

# Ideal TPU tile sizes; shrunk to divisors of the actual dims at trace time.
MXU_TILE = 128

# Token-axis tile cap. On real TPU this would be 128 (one MXU-height tile,
# double-buffered HBM->VMEM); on the CPU plugin every grid step lowers to a
# `while` iteration with dynamic-slice bookkeeping, so small models are
# fastest with a single tile. 512 keeps the whole token block under ~1 MB
# of "VMEM" at our widths while collapsing the grid to 1 for every lowered
# shape in this repo (§Perf L2: -48 while-loops per w4a4 score graph).
TOKEN_TILE_CAP = 512


def pick_tile(dim: int, cap: int = MXU_TILE) -> int:
    """Largest divisor of `dim` that is <= cap (TPU-aligned when possible)."""
    best = 1
    for t in range(1, min(dim, cap) + 1):
        if dim % t == 0:
            best = t
    return best


# ---------------------------------------------------------------------------
# quant_matmul: per-token fake-quant + GEMM
# ---------------------------------------------------------------------------


def _quant_matmul_kernel(x_ref, w_ref, o_ref, *, bits: float, clip: float):
    x = x_ref[...]
    qmin, qmax = ref.qlevels(int(bits))
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax * clip / qmax, 1e-8)
    q = jnp.clip(jnp.round(x / scale), qmin, qmax) * scale
    o_ref[...] = jnp.dot(q, w_ref[...], preferred_element_type=jnp.float32)


def quant_matmul(x: jnp.ndarray, w: jnp.ndarray, bits: int = 4,
                 clip: float = 1.0) -> jnp.ndarray:
    """``fake_quant_per_token(x, bits, clip) @ w`` as a fused Pallas kernel.

    x: [T, n] activations; w: [n, C] (already weight-quantized by the Rust
    pipeline). The token axis is tiled; each tile sees the full reduction
    dimension so the per-token scale is computed in one pass (on TPU this is
    the VMEM-resident row-max + MXU GEMM schedule).
    """
    t, n = x.shape
    n2, c = w.shape
    assert n == n2, f"shape mismatch {x.shape} @ {w.shape}"
    bt = pick_tile(t, TOKEN_TILE_CAP)
    bc = pick_tile(c, TOKEN_TILE_CAP)
    grid = (t // bt, c // bc)
    return pl.pallas_call(
        functools.partial(_quant_matmul_kernel, bits=float(bits), clip=float(clip)),
        out_shape=jax.ShapeDtypeStruct((t, c), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, n), lambda i, j: (i, 0)),
            pl.BlockSpec((n, bc), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bt, bc), lambda i, j: (i, j)),
        interpret=INTERPRET,
    )(x, w)


# ---------------------------------------------------------------------------
# kron_rotate: x (R1 ⊗ R2) via R1^T X_mat R2 per token
# ---------------------------------------------------------------------------


def _kron_rotate_kernel(x_ref, r1_ref, r2_ref, o_ref):
    bt = x_ref.shape[0]
    n1 = r1_ref.shape[0]
    n2 = r2_ref.shape[0]
    xm = x_ref[...].reshape(bt, n1, n2)
    r1 = r1_ref[...]
    r2 = r2_ref[...]
    # R1^T on the n1 axis, then R2 on the n2 axis; both factors stay resident
    # in VMEM across the token tile (double-buffered on real hardware).
    y = jax.lax.dot_general(xm, r1, (((1,), (0,)), ((), ())))  # [bt, n2, n1]
    y = jnp.swapaxes(y, 1, 2)                                  # [bt, n1, n2]
    z = jax.lax.dot_general(y, r2, (((2,), (0,)), ((), ())))   # [bt, n1, n2]
    o_ref[...] = z.reshape(bt, n1 * n2)


def kron_rotate(x: jnp.ndarray, r1: jnp.ndarray, r2: jnp.ndarray) -> jnp.ndarray:
    """Apply the Kronecker-structured rotation (Eq. 31) to token rows.

    Cost O(T·(n1²n2 + n1n2²)) = O(T·n^{3/2}) for balanced factors — the
    paper's headline transform-efficiency claim.
    """
    t, n = x.shape
    n1, n2 = r1.shape[0], r2.shape[0]
    assert n1 * n2 == n, f"kron factors {n1}x{n2} != {n}"
    bt = pick_tile(t, TOKEN_TILE_CAP)
    return pl.pallas_call(
        _kron_rotate_kernel,
        out_shape=jax.ShapeDtypeStruct((t, n), jnp.float32),
        grid=(t // bt,),
        in_specs=[
            pl.BlockSpec((bt, n), lambda i: (i, 0)),
            pl.BlockSpec((n1, n1), lambda i: (0, 0)),
            pl.BlockSpec((n2, n2), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bt, n), lambda i: (i, 0)),
        interpret=INTERPRET,
    )(x, r1, r2)


# ---------------------------------------------------------------------------
# hadamard: fast Walsh–Hadamard transform over the feature axis
# ---------------------------------------------------------------------------


def _hadamard_kernel(x_ref, o_ref, *, n: int):
    x = x_ref[...]
    bt = x.shape[0]
    y = x
    h = 1
    while h < n:  # log2(n) in-VMEM butterfly stages
        y = y.reshape(bt, n // (2 * h), 2, h)
        a = y[:, :, 0, :]
        b = y[:, :, 1, :]
        y = jnp.concatenate([(a + b)[:, :, None, :], (a - b)[:, :, None, :]], axis=2)
        h *= 2
    o_ref[...] = y.reshape(bt, n) * (1.0 / jnp.sqrt(float(n)))


def hadamard(x: jnp.ndarray) -> jnp.ndarray:
    """Normalized FWHT along the last axis (n must be a power of two)."""
    t, n = x.shape
    assert n & (n - 1) == 0, "hadamard dim must be a power of two"
    bt = pick_tile(t, TOKEN_TILE_CAP)
    return pl.pallas_call(
        functools.partial(_hadamard_kernel, n=n),
        out_shape=jax.ShapeDtypeStruct((t, n), jnp.float32),
        grid=(t // bt,),
        in_specs=[pl.BlockSpec((bt, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bt, n), lambda i: (i, 0)),
        interpret=INTERPRET,
    )(x)


# ---------------------------------------------------------------------------
# rtn_quant_weight: per-output-channel RTN fake quantization
# ---------------------------------------------------------------------------


def _rtn_kernel(w_ref, o_ref, *, bits: float, clip: float):
    w = w_ref[...]
    qmin, qmax = ref.qlevels(int(bits))
    absmax = jnp.max(jnp.abs(w), axis=0, keepdims=True)
    scale = jnp.maximum(absmax * clip / qmax, 1e-8)
    o_ref[...] = jnp.clip(jnp.round(w / scale), qmin, qmax) * scale


def rtn_quant_weight(w: jnp.ndarray, bits: int = 4, clip: float = 1.0) -> jnp.ndarray:
    """Per-output-channel symmetric RTN fake quantization of a [in, out] weight."""
    n, c = w.shape
    bc = pick_tile(c)
    return pl.pallas_call(
        functools.partial(_rtn_kernel, bits=float(bits), clip=float(clip)),
        out_shape=jax.ShapeDtypeStruct((n, c), jnp.float32),
        grid=(c // bc,),
        in_specs=[pl.BlockSpec((n, bc), lambda j: (0, j))],
        out_specs=pl.BlockSpec((n, bc), lambda j: (0, j)),
        interpret=INTERPRET,
    )(w)
