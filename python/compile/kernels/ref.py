"""Pure-jnp oracles for the Layer-1 Pallas kernels.

Every kernel in this package must match its oracle here to float tolerance;
`python/tests/test_kernels.py` enforces it (including hypothesis sweeps over
shapes). The oracles are also the semantic definition used by the Rust
reference forward (`rust/src/model/forward.rs`) — keep all three in sync.
"""
from __future__ import annotations

import jax.numpy as jnp


def qlevels(bits: int) -> tuple[float, float]:
    """Symmetric signed integer grid for `bits` (e.g. 4 -> [-8, 7])."""
    qmax = float(2 ** (bits - 1) - 1)
    qmin = -float(2 ** (bits - 1))
    return qmin, qmax


def fake_quant_per_token(x: jnp.ndarray, bits: int, clip: float = 1.0) -> jnp.ndarray:
    """Per-token (row-wise) symmetric absmax fake quantization.

    scale_t = clip * max_j |x_tj| / qmax ; q = clamp(round(x/scale)) * scale.
    Rows that are exactly zero pass through unchanged.
    """
    qmin, qmax = qlevels(bits)
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax * clip / qmax, 1e-8)
    q = jnp.clip(jnp.round(x / scale), qmin, qmax)
    return q * scale


def fake_quant_per_channel(w: jnp.ndarray, bits: int, clip: float = 1.0) -> jnp.ndarray:
    """Per-output-channel (column-wise for [in, out] weights) RTN fake quant."""
    qmin, qmax = qlevels(bits)
    absmax = jnp.max(jnp.abs(w), axis=0, keepdims=True)
    scale = jnp.maximum(absmax * clip / qmax, 1e-8)
    q = jnp.clip(jnp.round(w / scale), qmin, qmax)
    return q * scale


def quant_matmul(x: jnp.ndarray, w: jnp.ndarray, bits: int, clip: float = 1.0) -> jnp.ndarray:
    """W4A4-style GEMM oracle: per-token fake-quantize activations, then x_q @ w.

    `w` is expected to be pre-quantized (fake-quant f32) by the Rust pipeline;
    this op only quantizes the activation side.
    """
    return fake_quant_per_token(x, bits, clip) @ w


def kron_rotate(x: jnp.ndarray, r1: jnp.ndarray, r2: jnp.ndarray) -> jnp.ndarray:
    """x[T, n] -> x (R1 (x) R2) via the two-sided small-GEMM form (Eq. 31).

    Row-major reshape of each token row to (n1, n2), then R1^T X_mat R2.
    """
    t = x.shape[0]
    n1, n2 = r1.shape[0], r2.shape[0]
    xm = x.reshape(t, n1, n2)
    out = jnp.einsum("tij,ik->tkj", xm, r1)       # R1^T applied on the n1 axis
    out = jnp.einsum("tkj,jl->tkl", out, r2)      # R2 applied on the n2 axis
    return out.reshape(t, n1 * n2)


def hadamard(x: jnp.ndarray) -> jnp.ndarray:
    """x[T, n] H_n / sqrt(n) with H the Sylvester-Hadamard matrix, n = 2^k."""
    n = x.shape[-1]
    assert n & (n - 1) == 0, "hadamard dim must be a power of two"
    y = x
    h = 1
    while h < n:
        y = y.reshape(-1, n // (2 * h), 2, h)
        a = y[:, :, 0, :]
        b = y[:, :, 1, :]
        y = jnp.stack([a + b, a - b], axis=2)
        h *= 2
    return (y.reshape(x.shape) / jnp.sqrt(float(n))).astype(x.dtype)
