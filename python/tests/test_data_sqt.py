"""Data generation determinism + SQT round-trip + outlier folding."""
import os

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import data as D
from compile import model as M
from compile import sqt
from compile.train import fold_outliers, outlier_scale


class TestTokenizer:
    def test_roundtrip(self):
        s = "the weaving master zorbal kept a red heron ."
        assert D.decode(D.encode(s)) == s

    def test_specials(self):
        ids = D.encode("ab", bos=True, eos=True)
        assert ids[0] == D.BOS and ids[-1] == D.EOS
        assert D.decode(ids) == "ab"

    @settings(max_examples=20, deadline=None)
    @given(st.text(alphabet=st.characters(min_codepoint=32, max_codepoint=126),
                   max_size=60))
    def test_roundtrip_hypothesis(self, s):
        assert D.decode(D.encode(s)) == s


class TestWorld:
    def test_deterministic(self):
        w1, w2 = D.World(7), D.World(7)
        assert w1.names == w2.names
        assert w1.color == w2.color

    def test_corpus_deterministic(self):
        w = D.World(7)
        assert D.gen_wiki_corpus(w, 50, 1) == D.gen_wiki_corpus(w, 50, 1)
        assert D.gen_wiki_corpus(w, 50, 1) != D.gen_wiki_corpus(w, 50, 2)

    def test_tasks_answers_valid(self):
        w = D.World(7)
        tasks = D.gen_tasks(w, 20, seed=3)
        assert set(tasks) == {"facts_easy", "facts_hard", "continuation",
                              "lastword", "procedure", "pronoun"}
        for name, items in tasks.items():
            for it in items:
                assert 0 <= it["answer"] < len(it["options"])
                assert len(set(it["options"])) == len(it["options"])

    def test_mmlu_structure(self):
        w = D.World(7)
        m = D.gen_mmlu(w, 10, seed=4)
        assert set(m["domains"]) == {"stem", "hums", "social", "others"}
        for dom, shots in m["shots"].items():
            assert "question :" in shots and "answer :" in shots


class TestSqt:
    def test_roundtrip(self, tmp_path):
        path = os.path.join(tmp_path, "t.sqt")
        tensors = {
            "a": np.arange(12, dtype=np.float32).reshape(3, 4),
            "b": np.array([1, 2, 3], dtype=np.int32),
            "c": np.array([7], dtype=np.uint16),
            "d": np.frombuffer(b"hello", dtype=np.uint8),
        }
        sqt.save(path, tensors, {"k": "v", "n": 3})
        out, meta = sqt.load(path)
        assert meta == {"k": "v", "n": 3}
        for k in tensors:
            np.testing.assert_array_equal(out[k], tensors[k])
            assert out[k].dtype == tensors[k].dtype

    def test_scalarless_shapes(self, tmp_path):
        path = os.path.join(tmp_path, "s.sqt")
        sqt.save(path, {"x": np.zeros((2, 0, 3), np.float32)})
        out, _ = sqt.load(path)
        assert out["x"].shape == (2, 0, 3)


class TestOutlierFolding:
    def test_function_preserving(self):
        cfg = M.CONFIGS["sq-xs"]
        p = M.init_params(cfg, 0)
        folded = fold_outliers(cfg, p, seed=9)
        t = jnp.asarray(np.random.default_rng(0).integers(0, 260, (2, 12)),
                        jnp.int32)
        fp = [p[n] for n in M.param_layout(cfg, "fp")]
        fd = [folded[n] for n in M.param_layout(cfg, "fp")]
        (a,) = M.score_graph(cfg, "fp", t, *fp)
        (b,) = M.score_graph(cfg, "fp", t, *fd)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-3, atol=5e-3)

    def test_creates_outliers(self):
        """Post-norm activations must show massive-outlier channels."""
        cfg = M.CONFIGS["sq-xs"]
        p = M.init_params(cfg, 0)
        folded = fold_outliers(cfg, p, seed=9)
        g = np.asarray(folded["l00.an"])
        assert np.max(np.abs(g)) / np.median(np.abs(g)) > 5.0

    def test_scale_shape(self):
        s = outlier_scale(np.random.default_rng(0), 64)
        assert s.shape == (64,)
        assert np.sum(s > 8.0) >= 2  # massive channels present

    def test_moe_folding(self):
        cfg = M.CONFIGS["sq-moe"]
        p = M.init_params(cfg, 0)
        folded = fold_outliers(cfg, p, seed=9)
        t = jnp.asarray(np.random.default_rng(1).integers(0, 260, (1, 8)),
                        jnp.int32)
        fp = [p[n] for n in M.param_layout(cfg, "fp")]
        fd = [folded[n] for n in M.param_layout(cfg, "fp")]
        (a,) = M.score_graph(cfg, "fp", t, *fp)
        (b,) = M.score_graph(cfg, "fp", t, *fd)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-3, atol=5e-3)
