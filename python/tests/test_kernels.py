"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

Includes hypothesis sweeps over shapes (the kernels pick tile sizes from
divisors, so odd shapes exercise the tiling logic) and algebraic invariants
(orthogonality preservation, quantization grid membership).
"""
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import kernels
from compile.kernels import ref

RNG = np.random.default_rng(0)


def rand(shape, scale=1.0, seed=None):
    rng = np.random.default_rng(seed) if seed is not None else RNG
    return jnp.asarray(rng.normal(0, scale, size=shape).astype(np.float32))


def rand_orth(n, seed=0):
    q, _ = np.linalg.qr(np.random.default_rng(seed).normal(size=(n, n)))
    return jnp.asarray(q.astype(np.float32))


# ---------------------------------------------------------------------------
# quant_matmul
# ---------------------------------------------------------------------------


class TestQuantMatmul:
    def test_matches_ref(self):
        x, w = rand((24, 96)), rand((96, 64))
        np.testing.assert_allclose(kernels.quant_matmul(x, w, 4),
                                   ref.quant_matmul(x, w, 4), rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("bits", [2, 3, 4, 8])
    def test_bit_widths(self, bits):
        x, w = rand((8, 32)), rand((32, 16))
        np.testing.assert_allclose(kernels.quant_matmul(x, w, bits),
                                   ref.quant_matmul(x, w, bits), rtol=1e-5, atol=1e-5)

    def test_clip(self):
        x, w = rand((8, 32)), rand((32, 16))
        np.testing.assert_allclose(kernels.quant_matmul(x, w, 4, clip=0.7),
                                   ref.quant_matmul(x, w, 4, clip=0.7),
                                   rtol=1e-5, atol=1e-5)

    def test_zero_rows_pass_through(self):
        x = jnp.zeros((4, 16))
        w = rand((16, 8))
        out = kernels.quant_matmul(x, w, 4)
        np.testing.assert_allclose(np.asarray(out), np.zeros((4, 8)), atol=1e-7)

    def test_quantized_values_on_grid(self):
        """Fake-quantized activations must land on the int grid x scale."""
        x = rand((6, 20), scale=3.0)
        q = ref.fake_quant_per_token(x, 4)
        absmax = np.max(np.abs(np.asarray(x)), axis=1, keepdims=True)
        scale = absmax / 7.0
        ints = np.asarray(q) / scale
        np.testing.assert_allclose(ints, np.round(ints), atol=1e-4)
        assert ints.min() >= -8 - 1e-4 and ints.max() <= 7 + 1e-4

    @settings(max_examples=15, deadline=None)
    @given(t=st.integers(1, 40), n=st.integers(2, 48), c=st.integers(1, 40),
           bits=st.sampled_from([3, 4, 8]))
    def test_hypothesis_shapes(self, t, n, c, bits):
        x, w = rand((t, n), seed=t * 1000 + n), rand((n, c), seed=c)
        np.testing.assert_allclose(kernels.quant_matmul(x, w, bits),
                                   ref.quant_matmul(x, w, bits),
                                   rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# kron_rotate
# ---------------------------------------------------------------------------


class TestKronRotate:
    def test_matches_ref(self):
        x = rand((16, 96))
        r1, r2 = rand_orth(12, 1), rand_orth(8, 2)
        np.testing.assert_allclose(kernels.kron_rotate(x, r1, r2),
                                   ref.kron_rotate(x, r1, r2), rtol=1e-5, atol=1e-5)

    def test_equals_dense_kronecker(self):
        """The two-sided form must equal x @ (R1 (x) R2) exactly (Eq. 31)."""
        x = rand((5, 24))
        r1, r2 = rand_orth(6, 3), rand_orth(4, 4)
        dense = np.kron(np.asarray(r1), np.asarray(r2))
        expect = np.asarray(x) @ dense
        np.testing.assert_allclose(np.asarray(kernels.kron_rotate(x, r1, r2)),
                                   expect, rtol=1e-5, atol=1e-5)

    def test_norm_preserving(self):
        x = rand((7, 64), scale=5.0)
        r1, r2 = rand_orth(8, 5), rand_orth(8, 6)
        y = kernels.kron_rotate(x, r1, r2)
        np.testing.assert_allclose(np.linalg.norm(np.asarray(y), axis=1),
                                   np.linalg.norm(np.asarray(x), axis=1),
                                   rtol=1e-5)

    def test_identity_is_noop(self):
        x = rand((4, 32))
        y = kernels.kron_rotate(x, jnp.eye(4), jnp.eye(8))
        np.testing.assert_allclose(np.asarray(y), np.asarray(x), atol=1e-6)

    @settings(max_examples=12, deadline=None)
    @given(t=st.integers(1, 30), n1=st.integers(2, 10), n2=st.integers(2, 10))
    def test_hypothesis_shapes(self, t, n1, n2):
        x = rand((t, n1 * n2), seed=t * 100 + n1 * 10 + n2)
        r1, r2 = rand_orth(n1, n1), rand_orth(n2, n2)
        np.testing.assert_allclose(kernels.kron_rotate(x, r1, r2),
                                   ref.kron_rotate(x, r1, r2),
                                   rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# hadamard
# ---------------------------------------------------------------------------


class TestHadamard:
    @pytest.mark.parametrize("n", [2, 8, 64, 128])
    def test_matches_ref(self, n):
        x = rand((6, n))
        np.testing.assert_allclose(kernels.hadamard(x), ref.hadamard(x),
                                   rtol=1e-5, atol=1e-5)

    def test_orthogonal(self):
        h = np.asarray(kernels.hadamard(jnp.eye(32)))
        np.testing.assert_allclose(h @ h.T, np.eye(32), atol=1e-5)

    def test_involution_up_to_transpose(self):
        """H is symmetric for Sylvester construction: H(Hx) = x."""
        x = rand((5, 16))
        y = kernels.hadamard(kernels.hadamard(x))
        np.testing.assert_allclose(np.asarray(y), np.asarray(x), atol=1e-5)

    def test_spreads_spike(self):
        """A one-hot row maps to constant magnitude — the outlier-smoothing
        property QuaRot relies on."""
        x = jnp.zeros((1, 64)).at[0, 17].set(8.0)
        y = np.asarray(kernels.hadamard(x))
        np.testing.assert_allclose(np.abs(y), np.full((1, 64), 1.0), atol=1e-5)


# ---------------------------------------------------------------------------
# rtn weight quantizer
# ---------------------------------------------------------------------------


class TestRtnWeight:
    @pytest.mark.parametrize("bits", [3, 4, 8])
    def test_matches_ref(self, bits):
        w = rand((48, 36), scale=0.3)
        np.testing.assert_allclose(kernels.rtn_quant_weight(w, bits),
                                   ref.fake_quant_per_channel(w, bits),
                                   rtol=1e-5, atol=1e-5)

    def test_error_decreases_with_bits(self):
        w = rand((64, 32))
        errs = [float(jnp.mean((kernels.rtn_quant_weight(w, b) - w) ** 2))
                for b in (2, 4, 8)]
        assert errs[0] > errs[1] > errs[2]
