"""L2 correctness: graph families, parameter layout, and quant semantics."""
import numpy as np
import jax.numpy as jnp
import pytest

from compile import model as M
from compile.data import VOCAB_SIZE

CFG = M.CONFIGS["sq-xs"]


def params_and_rots(seed=0):
    p = M.init_params(CFG, seed)
    allp = dict(p)
    allp.update(M.identity_rotations(CFG))
    return p, allp


def flat(allp, mode):
    return [allp[n] for n in M.param_layout(CFG, mode)]


def toks(b, t, seed=0):
    return jnp.asarray(
        np.random.default_rng(seed).integers(0, VOCAB_SIZE, size=(b, t)),
        jnp.int32)


class TestLayout:
    def test_fp_layout_covers_all_weights(self):
        names = M.param_layout(CFG, "fp")
        assert names[0] == "emb.tok" and names[-1] == "out.head"
        assert len(names) == len(set(names))

    def test_quant_layout_extends_fp(self):
        fp = M.param_layout(CFG, "fp")
        q = M.param_layout(CFG, "w4a4")
        assert q[: len(fp)] == fp
        assert all(".rot_" in n or ".clip_" in n for n in q[len(fp):])

    def test_shapes_resolve(self):
        for mode in ("fp", "w4a4"):
            for n in M.param_layout(CFG, mode):
                M.param_shape(CFG, n)  # must not raise

    def test_moe_layout(self):
        moe = M.CONFIGS["sq-moe"]
        names = M.param_layout(moe, "fp")
        assert any(".router" in n for n in names)
        assert any(".x0.wg" in n for n in names)

    def test_kron_factor_algorithm1(self):
        """n2 must be the power of two dividing n nearest sqrt(n)."""
        for n in (64, 96, 128, 160, 256, 320, 416, 12):
            n1, n2 = M.kron_factor(n)
            assert n1 * n2 == n
            assert n2 & (n2 - 1) == 0
            best = min((a for a in [1 << k for k in range(20)] if n % a == 0),
                       key=lambda a: abs(a - n ** 0.5))
            assert n2 == best


class TestGraphs:
    def test_score_shapes(self):
        _, allp = params_and_rots()
        (lg,) = M.score_graph(CFG, "fp", toks(2, 12), *flat(allp, "fp"))
        assert lg.shape == (2, 12, VOCAB_SIZE)

    def test_identity_rotation_w4a16_equals_fp(self):
        """With identity rotations and no act quant the graph must be fp-exact."""
        _, allp = params_and_rots()
        t = toks(2, 10)
        (fp,) = M.score_graph(CFG, "fp", t, *flat(allp, "fp"))
        (wa,) = M.score_graph(CFG, "w4a16", t, *flat(allp, "w4a16"))
        np.testing.assert_allclose(np.asarray(fp), np.asarray(wa),
                                   rtol=1e-4, atol=1e-4)

    def test_rotation_invariance_w4a16(self):
        """Rotating activations online and weights offline must cancel (Eq. 1)."""
        p, allp = params_and_rots()
        t = toks(2, 8, seed=3)
        (fp,) = M.score_graph(CFG, "fp", t, *flat(allp, "fp"))

        rng = np.random.default_rng(5)
        rot = dict(allp)
        d = CFG.d_model
        n1, n2 = M.kron_factor(d)
        q1, _ = np.linalg.qr(rng.normal(size=(n1, n1)))
        q2, _ = np.linalg.qr(rng.normal(size=(n2, n2)))
        r = np.kron(q1, q2).astype(np.float32)
        for i in range(CFG.n_layers):
            pre = f"l{i:02d}"
            rot[f"{pre}.rot_qkv.r1"] = jnp.asarray(q1.astype(np.float32))
            rot[f"{pre}.rot_qkv.r2"] = jnp.asarray(q2.astype(np.float32))
            for w in ("wq", "wk", "wv"):
                rot[f"{pre}.{w}"] = jnp.asarray(r.T @ np.asarray(allp[f"{pre}.{w}"]))
        (wa,) = M.score_graph(CFG, "w4a16", t, *flat(rot, "w4a16"))
        np.testing.assert_allclose(np.asarray(fp), np.asarray(wa),
                                   rtol=2e-3, atol=2e-3)

    def test_w4a4_differs_but_close(self):
        _, allp = params_and_rots()
        t = toks(2, 10)
        (fp,) = M.score_graph(CFG, "fp", t, *flat(allp, "fp"))
        (q,) = M.score_graph(CFG, "w4a4", t, *flat(allp, "w4a4"))
        diff = float(jnp.abs(fp - q).mean())
        assert 0 < diff < 10.0

    def test_decode_matches_score(self):
        """Autoregressive decode against the KV cache must reproduce the
        full-sequence score logits position by position."""
        _, allp = params_and_rots()
        fl = flat(allp, "fp")
        t = toks(2, 9, seed=7)
        (sc,) = M.score_graph(CFG, "fp", t, *fl)
        lg, kc, vc = M.prefill_graph(CFG, "fp", t[:, :6], *fl)
        np.testing.assert_allclose(np.asarray(lg[:, :6]), np.asarray(sc[:, :6]),
                                   rtol=1e-4, atol=1e-4)
        for pos in range(6, 9):
            posv = jnp.asarray([pos, pos], jnp.int32)
            lg, kc, vc = M.decode_graph(CFG, "fp", t[:, pos], posv,
                                        kc, vc, *fl)
            np.testing.assert_allclose(np.asarray(lg), np.asarray(sc[:, pos]),
                                       rtol=1e-4, atol=2e-4)

    def test_decode_ragged_positions(self):
        """Slots at different positions must decode independently."""
        _, allp = params_and_rots()
        fl = flat(allp, "fp")
        t = toks(2, 8, seed=11)
        (sc,) = M.score_graph(CFG, "fp", t, *fl)
        # row 0 prefilled 4 tokens, row 1 prefilled 6
        lg, kc, vc = M.prefill_graph(CFG, "fp", t[:, :6], *fl)
        # zero out row 0's cache beyond its true length to mimic ragged fill
        kc = kc.at[:, 0, :, 4:, :].set(0.0)
        vc = vc.at[:, 0, :, 4:, :].set(0.0)
        posv = jnp.asarray([4, 6], jnp.int32)
        tokv = jnp.asarray([t[0, 4], t[1, 6]], jnp.int32)
        lg, kc, vc = M.decode_graph(CFG, "fp", tokv, posv, kc, vc, *fl)
        np.testing.assert_allclose(np.asarray(lg[0]), np.asarray(sc[0, 4]),
                                   rtol=1e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(lg[1]), np.asarray(sc[1, 6]),
                                   rtol=1e-4, atol=2e-4)

    def test_moe_forward(self):
        moe = M.CONFIGS["sq-moe"]
        p = M.init_params(moe, 1)
        allp = dict(p)
        allp.update(M.identity_rotations(moe))
        fl = [allp[n] for n in M.param_layout(moe, "fp")]
        (lg,) = M.score_graph(moe, "fp", toks(2, 8), *fl)
        assert lg.shape == (2, 8, VOCAB_SIZE)
        assert bool(jnp.all(jnp.isfinite(lg)))

    def test_loss_decreases_direction(self):
        p, _ = params_and_rots()
        loss = float(M.lm_loss(CFG, p, toks(4, 24)))
        assert 4.0 < loss < 8.0  # ~ln(260) at init
